"""repro.sched: chunk planning, pipeline schedule/executor, overlap cost
model, commsim overlap systems, and end-to-end ``exec_mode="pipeline"``
bit-identity on 8 forced host devices (DESIGN.md §6)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st   # optional dep; skips when absent

from repro.comm import Topology
from repro.sched import (format_schedule, optimal_chunks, overlap_ms,
                         pipeline_schedule, plan_chunks, run_pipeline,
                         sync_ms)

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# chunk planning
# ---------------------------------------------------------------------------

def test_plan_chunks_even_split():
    p = plan_chunks(64, 4)
    assert p.sizes == (16, 16, 16, 16)
    assert p.offsets == (0, 16, 32, 48)
    assert p.slices() == ((0, 16), (16, 16), (32, 16), (48, 16))


def test_plan_chunks_uneven_and_clipped():
    p = plan_chunks(40, 3)
    assert p.sizes == (16, 16, 8)          # remainder on leading chunks
    assert sum(p.sizes) == 40
    assert plan_chunks(16, 100).sizes == (8, 8)   # clipped to C/8
    assert plan_chunks(8, 4).sizes == (8,)        # never empty chunks
    with pytest.raises(AssertionError):
        plan_chunks(12, 2)                 # capacity must be 8-aligned


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.integers(1, 12))
def test_plan_chunks_properties(units, n):
    cap = units * 8
    p = plan_chunks(cap, n)
    assert sum(p.sizes) == cap
    assert all(s > 0 and s % 8 == 0 for s in p.sizes)
    assert p.n_chunks == min(n, units)
    assert max(p.sizes) - min(p.sizes) <= 8    # near-even split
    # offsets tile the capacity contiguously
    assert p.offsets[0] == 0
    assert all(o + s == o2 for (o, s), o2 in
               zip(p.slices(), p.offsets[1:] + (cap,)))


# ---------------------------------------------------------------------------
# pipeline schedule / executor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 3, 5, 8])
def test_pipeline_schedule_invariants(n):
    sched = pipeline_schedule(n)
    pos = {(s.name, s.chunk): i for i, s in enumerate(sched)}
    assert len(pos) == len(sched) == 3 * n         # no duplicates
    outstanding, peak = set(), 0
    for s in sched:
        if s.name == "dispatch":
            outstanding.add(s.chunk)
        elif s.name == "compute":
            outstanding.discard(s.chunk)
        peak = max(peak, len(outstanding))
    assert peak <= 2                               # double-buffered
    for k in range(n):
        assert pos[("dispatch", k)] < pos[("compute", k)] \
            < pos[("combine", k)]
        if k + 1 < n:
            # chunk k+1's collective is in flight while chunk k computes
            assert pos[("dispatch", k + 1)] < pos[("compute", k)]
    text = format_schedule(n)
    assert "dispatch[0]" in text and f"compute[{n - 1}]" in text


@pytest.mark.parametrize("barrier", [True, False])
def test_run_pipeline_matches_direct_execution(rng, barrier):
    x = jnp.asarray(rng.standard_normal((6, 5)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((5, 5)), jnp.float32)

    def go(xx):
        outs, combs = run_pipeline(
            6,
            dispatch=lambda k: xx[k] * 2.0,
            compute=lambda k, p: p @ w + k,
            combine=lambda k, o: o.sum(),
            barrier=barrier)
        return jnp.stack(outs), jnp.stack(combs)

    outs, combs = jax.jit(go)(x)
    want = jnp.stack([x[k] * 2.0 @ w + k for k in range(6)])
    np.testing.assert_allclose(np.asarray(outs), np.asarray(want),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(combs),
                               np.asarray(want.sum(-1)), rtol=1e-6)
    # differentiable end to end (the train step backprops through it)
    g = jax.grad(lambda xx: go(xx)[1].sum())(x)
    assert np.isfinite(np.asarray(g)).all()


def test_run_pipeline_without_combine():
    outs, combs = run_pipeline(3, dispatch=lambda k: jnp.float32(k),
                               compute=lambda k, p: p + 1)
    assert combs is None
    assert [float(o) for o in outs] == [1.0, 2.0, 3.0]


# ---------------------------------------------------------------------------
# overlap cost model
# ---------------------------------------------------------------------------

def test_overlap_cost_model_contracts():
    topo = Topology(num_nodes=2, devices_per_node=4)
    kw = dict(dispatch_ms=1000.0, ffn_ms=600.0, combine_ms=800.0)
    assert overlap_ms(topo, 1, **kw) == pytest.approx(sync_ms(topo, **kw))
    # monotone non-increasing from 1 chunk to the optimum
    n_opt, t_opt = optimal_chunks(topo, max_chunks=16, **kw)
    ts = [overlap_ms(topo, n, **kw) for n in range(1, n_opt + 1)]
    assert all(a >= b - 1e-9 for a, b in zip(ts, ts[1:]))
    assert t_opt == pytest.approx(ts[-1])
    # pipelining can't beat the slowest stage, and must beat sync
    assert t_opt > max(kw.values()) - 1e-9
    assert t_opt < sync_ms(topo, **kw)
    # heavy per-chunk overhead pushes the optimum back toward 1 chunk
    n_hv, _ = optimal_chunks(topo, max_chunks=16,
                             chunk_overhead_ms=500.0, **kw)
    assert n_hv < n_opt
    # message latencies enter the per-chunk cost
    lat = Topology(num_nodes=2, devices_per_node=4, intra_lat=1e-3,
                   inter_lat=1e-2)
    assert overlap_ms(lat, 4, **kw) > overlap_ms(topo, 4, **kw)


def test_commsim_overlap_systems():
    from repro.configs import get_config
    from repro.core import commsim
    cfg = get_config("moe-gpt2", num_experts=8)
    setup = commsim.PaperSetup(cfg=cfg)
    comp, comm = commsim.PAPER_VANILLA["moe-gpt2"][8]
    cal = commsim.calibrate(setup, comp, comm)
    topo = commsim.default_topology(8, nodes=2, bw_ratio=4.0)
    for system in ("vanilla-overlap", "luffy-overlap"):
        hier = commsim.predict(setup, cal,
                               system=system.replace("overlap", "hier"),
                               topo=topo)
        ov = commsim.predict(setup, cal, system=system, topo=topo)
        # sync baseline is the hier prediction (same bytes, no overlap)
        # plus the two one-shot collective launch overheads
        from repro.sched.cost import DEFAULT_CHUNK_OVERHEAD_MS
        assert ov["sync_ms"] == pytest.approx(
            hier["comp_ms"] + hier["comm_ms"],
            abs=2 * DEFAULT_CHUNK_OVERHEAD_MS + 1e-6)
        # paper-ratio acceptance: >= 1.2x predicted end-to-end speedup
        assert ov["sync_ms"] / ov["step_ms"] >= 1.2
        # explicit chunk counts are monotone non-increasing to the opt
        steps = [commsim.predict(setup, cal, system=system, topo=topo,
                                 chunks=n)["step_ms"]
                 for n in range(1, ov["chunks"] + 1)]
        assert all(a >= b - 1e-9 for a, b in zip(steps, steps[1:]))
        assert steps[-1] == pytest.approx(ov["step_ms"])


def test_fig_overlap_sweep_contracts():
    """The benchmark's own JSON contracts (it raises when violated)."""
    sys.path.insert(0, ROOT)
    from benchmarks import fig_overlap_sweep
    out = fig_overlap_sweep.sweep()
    paper = out["ratios"][f"{out['paper_bw_ratio']:g}"]
    assert all(rec["speedup"] >= 1.2 for rec in paper.values())


# ---------------------------------------------------------------------------
# exec_mode="pipeline" — single-device fallback + 8-device bit-identity
# ---------------------------------------------------------------------------

def test_pipeline_single_device_falls_back_to_sync(rng):
    import dataclasses
    from repro.config import LuffyConfig, ModelConfig, MoEConfig
    from repro.core import moe_layer as ml
    cfg = ModelConfig(
        name="t", kind="decoder", family="moe", num_layers=2,
        d_model=32, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=64),
        layer_ffn_pattern=("moe",), compute_dtype="float32",
        param_dtype="float32")
    p = ml.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    sb = {"labels": jnp.zeros((2, 16), jnp.int32),
          "seq_len": jnp.full((2,), 16, jnp.int32)}
    base = LuffyConfig(enable_condensation=False, enable_migration=False)
    pipe = dataclasses.replace(base, exec_mode="pipeline",
                               pipeline_chunks=4)
    ys, *_ = ml.moe_core(p, x, dict(sb), cfg, base, mode="vanilla",
                         capacity=256, axis_name=None,
                         threshold=jnp.float32(1.0))
    yp, *_ = ml.moe_core(p, x, dict(sb), cfg, pipe, mode="vanilla",
                         capacity=256, axis_name=None,
                         threshold=jnp.float32(1.0))
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(yp))


def _run(script_body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import itertools
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.comm import Topology, make_mesh
        from repro.configs import get_config
        from repro.config import reduced, LuffyConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.dist import DistContext
        from repro.data import SyntheticLM
        from repro.core.moe_layer import capacity_for

        cfg = reduced(get_config("moe-gpt2"), num_layers=2, d_model=128)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        shape = ShapeConfig("t", 64, 8, "train")
        data = SyntheticLM(cfg, shape)
        b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        cap = capacity_for(cfg.moe, 64, cfg.moe.num_experts, slack=8.0)

        def loss(dist, luffy):
            l, m = jax.jit(lambda p, bb: model.train_loss(
                p, bb, jnp.float32(0.4), luffy=luffy, dist=dist,
                capacity=cap))(params, b)
            return float(l), m
    """) + textwrap.dedent(script_body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_bit_identical_flat_all_combos():
    """{migration, condensation} on/off × pipeline chunks on the flat
    comm path: pipeline == sync bit-for-bit (same mesh, same batch)."""
    out = _run("""
        mesh = make_mesh((2, 4), ("data", "model"))
        dist = DistContext(mesh, batch_axes=("data", "model"),
                           seq_axis=None, fsdp_axes=("data",),
                           model_axis="model", topology=Topology.flat(4))
        for mig, cond in itertools.product((True, False), repeat=2):
            base = LuffyConfig(enable_condensation=cond,
                               enable_migration=mig, combine_slack=4.0,
                               condense_group=32, comm_mode="flat")
            chunk_counts = (3, 8) if (mig and cond) else (3,)
            ls, ms = loss(dist, base)
            for nc in chunk_counts:
                pipe = dataclasses.replace(base, exec_mode="pipeline",
                                           pipeline_chunks=nc)
                lp, mp = loss(dist, pipe)
                assert ls == lp, (mig, cond, nc, ls, lp)
                for k in ms:
                    assert float(ms[k]) == float(mp[k]), (mig, cond, k)
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_bit_identical_hier_all_combos():
    """Same four combos through the hierarchical two-phase collectives
    on a (2 node × 2 local) mesh."""
    out = _run("""
        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        dist = DistContext(mesh, batch_axes=("data", "node", "local"),
                           seq_axis=None, fsdp_axes=("data",),
                           model_axis=("node", "local"),
                           topology=Topology(2, 2))
        for mig, cond in itertools.product((True, False), repeat=2):
            base = LuffyConfig(enable_condensation=cond,
                               enable_migration=mig, combine_slack=4.0,
                               condense_group=32, comm_mode="hier")
            pipe = dataclasses.replace(base, exec_mode="pipeline",
                                       pipeline_chunks=3)
            ls, ms = loss(dist, base)
            lp, mp = loss(dist, pipe)
            assert ls == lp, (mig, cond, ls, lp)
            for k in ms:
                assert float(ms[k]) == float(mp[k]), (mig, cond, k)
        print("OK")
    """)
    assert "OK" in out
