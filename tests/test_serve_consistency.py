"""Decode-path consistency: token-by-token decode must reproduce the
full-sequence forward — validates SSM state threading (mamba, rwkv6),
the KV ring buffer for windowed layers, and chunked-local attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve_lib
from repro.config import AttnConfig, LuffyConfig, reduced
from repro.configs import get_config
from repro.dist import single_device
from repro.models import ssm as ssm_mod
from repro.models.model import build_model

DIST = single_device()
LUFFY = LuffyConfig(enable_condensation=False, enable_migration=False)


def _decode_logits_chain(cfg, params, toks, s_max):
    cache = serve_lib.cache_struct(cfg, toks.shape[0], s_max,
                                   as_struct=False)
    lg = None
    for t in range(toks.shape[1]):
        lg, cache = serve_lib.decode_step(params, cfg, LUFFY, DIST, cache,
                                          toks[:, t:t + 1])
    return lg


@pytest.mark.parametrize("arch", ["rwkv6-3b", "hymba-1.5b"])
def test_ssm_decode_matches_prefill(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    r = np.random.default_rng(0)
    toks = jnp.asarray(r.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    lg_full, _ = serve_lib.prefill(params, cfg, LUFFY, DIST, toks, S)
    lg_chain = _decode_logits_chain(cfg, params, toks, S + 2)
    np.testing.assert_allclose(np.asarray(lg_chain), np.asarray(lg_full),
                               atol=5e-3, rtol=5e-3)


def test_window_ring_buffer_matches_full_cache():
    """A windowed layer with ring cache (W < S) must equal the same
    model decoded with an oversized (full) cache."""
    cfg = reduced(get_config("starcoder2-15b"))
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    # shrink the window below the sequence length so the ring wraps
    cfg = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, window_pattern=(8,)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 20
    r = np.random.default_rng(1)
    toks = jnp.asarray(r.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    # ring cache: W = 8 (wraps twice)
    lg_ring = _decode_logits_chain(cfg, params, toks, 8)
    # full-cache reference: window pattern widened so W == s_max but the
    # ATTENTION mask still limits to 8 — emulate by keeping window=8 and
    # a cache of size >= S (no wrap; mask does the limiting)
    cache = serve_lib.cache_struct(cfg, B, 32, as_struct=False)
    lg_full = None
    for t in range(S):
        lg_full, cache = serve_lib.decode_step(params, cfg, LUFFY, DIST,
                                               cache, toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(lg_ring), np.asarray(lg_full),
                               atol=2e-3, rtol=2e-3)


def test_chunked_local_decode_matches_prefill():
    """llama4-style chunked-local attention: decode over chunk
    boundaries must match the full forward."""
    cfg = reduced(get_config("llama4-maverick-400b-a17b"))
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, S = 2, 16   # window (reduced) = 64 -> single chunk; shrink it
    cfg2 = dataclasses.replace(
        cfg, attn=dataclasses.replace(
            cfg.attn, window_pattern=(6, 6, 6, None)))
    # random tokens: degenerate identical tokens all route to one expert
    # and the PREFILL hits capacity drops that single-token decode never
    # sees — a real (documented) capacity semantics difference, not a bug
    r2 = np.random.default_rng(7)
    toks = jnp.asarray(r2.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    lg_full, _ = serve_lib.prefill(params, cfg2, LUFFY, DIST, toks, S)
    lg_chain = _decode_logits_chain(cfg2, params, toks, S + 2)
    np.testing.assert_allclose(np.asarray(lg_chain), np.asarray(lg_full),
                               atol=5e-3, rtol=5e-3)


# ---------------------------------------------------------------------------
# continuous batching: slot recycling (DESIGN.md §13)
# ---------------------------------------------------------------------------

def _decode_slot0_logits(cfg, params, cache, seq, other):
    """Feed ``seq`` into slot 0 and ``other`` into slot 1, one token per
    step; return slot 0's logits at every step."""
    out = []
    for t in range(seq.shape[0]):
        toks = jnp.stack([seq[t], other[t]])[:, None]
        lg, cache = serve_lib.decode_step(params, cfg, LUFFY, DIST, cache,
                                          toks)
        out.append(np.asarray(lg[0]))
    return np.asarray(out), cache


@pytest.mark.parametrize("arch,window", [("moe-gpt2", None),
                                         ("moe-gpt2", 6),
                                         ("rwkv6-3b", None)])
def test_admit_recycled_slot_bitwise_equals_fresh(arch, window):
    """Acceptance (ISSUE 8): a sequence admitted mid-stream into a
    recycled cache slot produces BITWISE-identical logits to the same
    sequence decoded in a fresh batch. Covers the attention ring (stale
    k/v/cpos entries are masked, not cleared — the slot-recycling
    invariant in repro.serve.engine), the wrapped-window ring, and the
    recurrent-state zeroing in admit_slot (rwkv6)."""
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              compute_dtype="float32")
    if window is not None:
        cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(cfg.attn,
                                          window_pattern=(window,)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    B, s_max = 2, 16
    r = np.random.default_rng(3)
    # first occupants run long enough to wrap the 6-token window ring,
    # so the recycled slot holds stale entries at every ring index
    warm = jnp.asarray(r.integers(1, cfg.vocab_size, (B, 9)), jnp.int32)
    seq = jnp.asarray(r.integers(1, cfg.vocab_size, (7,)), jnp.int32)
    other = jnp.asarray(r.integers(1, cfg.vocab_size, (7,)), jnp.int32)

    # stream: decode the first occupants, evict slot 0, admit seq there
    cache = serve_lib.cache_struct(cfg, B, s_max, as_struct=False)
    for t in range(warm.shape[1]):
        _, cache = serve_lib.decode_step(params, cfg, LUFFY, DIST, cache,
                                         warm[:, t:t + 1])
    cache = serve_lib.admit_slot(cache, 0, int(cache["pos"]))
    got, _ = _decode_slot0_logits(cfg, params, cache, seq, other)

    # reference: the same sequence decoded from a FRESH cache. Slot 1's
    # history differs between the two runs, which must not leak into
    # slot 0 (per-slot attention frames; decode capacity admits every
    # (token, expert) assignment, so MoE dispatch never drops).
    fresh = serve_lib.cache_struct(cfg, B, s_max, as_struct=False)
    want, _ = _decode_slot0_logits(cfg, params, fresh, seq, other)
    assert np.isfinite(got).all()
    np.testing.assert_array_equal(got, want)
