"""repro.serve subsystem (DESIGN.md §13): decode plan templates
(zero-planning steady-state decode, bit-identical to the unplanned
path), the continuous-batching scheduler's state machine and SLO
accounting, decode-step cost pricing + the autotune decode_overlap
candidate, the serve_lib compatibility shim, and the 8-device
sync-vs-decode_overlap bit-identity."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve_lib
from repro.comm.topology import Topology
from repro.config import LuffyConfig, reduced
from repro.configs import get_config
from repro.dist import single_device
from repro.models.model import build_model
from repro.obs import autotune as obs_at
from repro.plan import PlanCache
from repro.plan import exchange as pexch
from repro.plan.cache import decode_plan_key, precompute_decode_plans
from repro.sched.cost import decode_combine_ms, decode_step_ms
from repro.serve import engine
from repro.serve.scheduler import (DECODE, DONE, IDLE_TOKEN, PREFILL,
                                   QUEUED, ContinuousScheduler)

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# compatibility shim
# ---------------------------------------------------------------------------

def test_serve_lib_shim_reexports_engine():
    """repro.serve_lib re-exports the promoted engine (the
    core/condensation.py -> repro.condense discipline): same objects,
    not copies, so monkeypatching either module sees one function."""
    for name in serve_lib.__all__:
        assert getattr(serve_lib, name) is getattr(engine, name), name


# ---------------------------------------------------------------------------
# decode plan templates (zero-planning steady state)
# ---------------------------------------------------------------------------

def test_decode_plan_key_defaults_to_decode_capacity():
    """The key's default capacity is the engine's decode_capacity — the
    single shared derivation; drift would silently miss the cache."""
    cfg = reduced(get_config("moe-gpt2"), num_layers=2, d_model=64)
    nl = LuffyConfig(enable_condensation=False, enable_migration=False)
    dist = single_device()
    cap = engine.decode_capacity(cfg, dist, 4)
    assert decode_plan_key(cfg, nl, dist, 4) == \
        decode_plan_key(cfg, nl, dist, 4, capacity=cap)
    # the batch is part of the key: different shapes never collide
    assert decode_plan_key(cfg, nl, dist, 4) != \
        decode_plan_key(cfg, nl, dist, 8)


def test_decode_warm_cache_zero_planning_calls(tmp_path):
    """Acceptance (ISSUE 8): with a warm decode template, steady-state
    decode performs ZERO build_exchange_plan calls (every MoE sublayer
    instantiates the cached template) and its logits are bit-identical
    to the unplanned decode path."""
    cfg = dataclasses.replace(
        reduced(get_config("moe-gpt2"), num_layers=2, d_model=64),
        compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dist = single_device()
    nl = LuffyConfig(enable_condensation=False, enable_migration=False)
    B, steps = 2, 4
    r = np.random.default_rng(0)
    toks = jnp.asarray(r.integers(1, cfg.vocab_size, (B, steps)),
                       jnp.int32)
    cache0 = serve_lib.cache_struct(cfg, B, 8, as_struct=False)

    pcache = PlanCache(tmp_path)
    key = precompute_decode_plans(cfg, nl, dist, B, pcache)
    assert pcache.get(key) is not None

    base = pexch.BUILD_CALLS
    cold = jax.jit(lambda p, c, t: serve_lib.decode_step(
        p, cfg, nl, dist, c, t)).lower(params, cache0, toks[:, :1])
    # one build per MoE pattern position (the layer scan traces once)
    assert pexch.BUILD_CALLS - base == 1

    base = pexch.BUILD_CALLS
    warm = jax.jit(lambda p, c, t: serve_lib.decode_step(
        p, cfg, nl, dist, c, t, plan_cache=pcache)).lower(
            params, cache0, toks[:, :1])
    assert pexch.BUILD_CALLS - base == 0   # zero planning at decode
    assert pcache.hits >= 1

    fc, fw = cold.compile(), warm.compile()
    cc = cw = cache0
    for t in range(steps):
        lgc, cc = fc(params, cc, toks[:, t:t + 1])
        lgw, cw = fw(params, cw, toks[:, t:t + 1])
        np.testing.assert_array_equal(np.asarray(lgc), np.asarray(lgw))
    assert np.isfinite(np.asarray(lgc)).all()


# ---------------------------------------------------------------------------
# continuous-batching scheduler (virtual clock)
# ---------------------------------------------------------------------------

def _prompt(*ids):
    return np.asarray(ids, np.int32)


def test_scheduler_fifo_admission_and_slot_churn():
    s = ContinuousScheduler(2)
    a = s.submit(_prompt(5), 1, now=0.0)
    b = s.submit(_prompt(6), 1, now=0.0)
    c = s.submit(_prompt(7), 1, now=0.0)
    assert [r.state for r in (a, b, c)] == [QUEUED] * 3
    adm = s.admit(now=1.0)
    # FIFO into the free slots; c waits
    assert [(sl, r.rid) for sl, r in adm] == [(0, a.rid), (1, b.rid)]
    assert c.state == QUEUED and s.active_slots == 2
    assert s.slot_churn == 0           # first occupancy is not churn
    # a finishes (1-token prompt: its first logits produce the single
    # generated token), slot 0 frees, c recycles it -> churn
    s.next_feed()
    s.observe(np.zeros((2, 8), np.float32), now=2.0)
    assert a.state == DONE and s.slots[0] is None
    adm = s.admit(now=3.0)
    assert adm == [(0, c)]
    assert s.slot_churn == 1
    assert not s.all_done()


def test_scheduler_feed_states_and_slo_accounting():
    s = ContinuousScheduler(2)
    req = s.submit(_prompt(3, 4, 5), 2, now=10.0)
    s.admit(now=10.5)
    assert req.state == PREFILL
    lg = np.zeros((2, 8), np.float32)
    lg[:, 6] = 1.0                     # argmax -> token 6
    # prompt fed token-by-token; mid-prompt logits are discarded
    for want in (3, 4, 5):
        feed = s.next_feed()
        assert feed.shape == (2, 1) and feed.dtype == np.int32
        assert feed[0, 0] == want
        assert feed[1, 0] == IDLE_TOKEN   # empty slot feeds the idle id
        s.observe(lg, now=11.0 if want == 5 else 10.6)
    # the last prompt logits produced the first generated token
    assert req.state == DECODE and req.generated == [6]
    assert req.first_token_time == 11.0
    # decode feeds the request's own last token back
    assert s.next_feed()[0, 0] == 6
    s.observe(lg, now=12.0)
    assert req.state == DONE and req.finish_time == 12.0
    assert s.slots[0] is None          # evicted on finish
    assert s.all_done()
    # SLOs: queue 10.0->10.5, ttft 10.0->11.0, tpot (12.0-11.0)/1
    assert req.queue_ms == pytest.approx(500.0)
    assert req.ttft_ms == pytest.approx(1000.0)
    assert req.tpot_ms == pytest.approx(1000.0)


def test_scheduler_step_metrics_deltas():
    s = ContinuousScheduler(1)
    s.submit(_prompt(2), 1, now=0.0)
    s.submit(_prompt(3), 1, now=0.0)
    s.admit(now=0.0)
    s.next_feed()
    s.observe(np.zeros((1, 8), np.float32), now=1.0)
    m1 = s.step_metrics()
    assert m1["admitted"] == 1.0 and m1["finished"] == 1.0
    assert m1["generated_tokens"] == 1.0
    assert m1["queued_requests"] == 1.0 and m1["active_slots"] == 0.0
    assert "ttft_ms" in m1             # a request finished this step
    s.admit(now=2.0)
    m2 = s.step_metrics()              # deltas, not cumulative values
    assert m2["admitted"] == 1.0 and m2["finished"] == 0.0
    assert m2["slot_churn"] == 1.0     # recycled the only slot
    assert "ttft_ms" not in m2         # nothing finished this step


# ---------------------------------------------------------------------------
# decode-step pricing (sched.cost + autotune)
# ---------------------------------------------------------------------------

def test_decode_cost_pricing():
    topo = Topology(2, 4)
    assert decode_combine_ms(8, 256, Topology.flat(1)) == 0.0
    assert decode_combine_ms(0, 256, topo) == 0.0
    ms = decode_combine_ms(8, 256, topo)
    assert ms > 0.0
    # hier fabric prices the slow inter-node links; a flat fabric of the
    # same size rides the fast intra links
    assert ms > decode_combine_ms(8, 256, Topology.flat(8))
    assert decode_combine_ms(16, 256, topo) > ms    # payload-monotone
    # overlap hides the shorter leg behind the longer
    assert decode_step_ms(combine_ms=3.0, shared_ffn_ms=2.0,
                          overlap=False) == 5.0
    assert decode_step_ms(combine_ms=3.0, shared_ffn_ms=2.0,
                          overlap=True) == 3.0


def test_autotune_grid_and_decode_pricing():
    topo = Topology(2, 4)
    grid = obs_at.candidate_grid(topo)
    assert grid[0] == obs_at.DEFAULT_KNOBS
    dec = [k for k in grid if k["exec_mode"] == "decode_overlap"]
    assert dec                          # the candidate is in the grid
    # dedup wire stays sync-scope: never paired with decode_overlap
    assert all(k["hier_dedup"] == "off" for k in dec)
    kw = dict(topo=topo, tokens=512, top_k=2, d_model=256, d_ff=512,
              num_layers=4, n_moe=4, n_slots=8, num_experts=8,
              decode_tokens=8, d_ff_shared=512)
    sync = obs_at.modeled_step_components(obs_at.DEFAULT_KNOBS, **kw)
    ovl = obs_at.modeled_step_components(dec[0], **kw)
    assert sync["decode_ms"] > 0.0
    assert ovl["decode_ms"] < sync["decode_ms"]   # overlap models faster
    # on the build/execute path decode_overlap prices exactly like sync
    assert ovl["exchange_ms"] == sync["exchange_ms"]
    # train workloads (decode_tokens=0) never see the term
    kw.update(decode_tokens=0, d_ff_shared=0)
    assert obs_at.modeled_step_components(dec[0], **kw)["decode_ms"] \
        == 0.0


def test_autotune_picks_decode_overlap_for_decode_heavy_workload():
    """When the decode term dominates (big shared FFN to hide the
    combine behind), the search must choose exec_mode=decode_overlap;
    the winning total is the ledger's modeled decode saving."""
    topo = Topology(2, 4)
    tuned = obs_at.autotune_config(
        topo=topo, tokens=64, top_k=2, d_model=512, d_ff=1024,
        num_layers=4, n_slots=8, num_experts=8,
        decode_tokens=64, d_ff_shared=4096)
    assert tuned.knobs["exec_mode"] == "decode_overlap"
    assert tuned.modeled_step_ms <= tuned.default_step_ms
    assert tuned.workload["decode_tokens"] == 64


# ---------------------------------------------------------------------------
# 8-device golden grid (subprocess, like test_plan_cache/test_multidevice)
# ---------------------------------------------------------------------------

def _run(script_body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from repro import serve_lib
        from repro.comm import Topology, make_mesh
        from repro.configs import get_config
        from repro.config import reduced, LuffyConfig
        from repro.models.model import build_model
        from repro.dist import DistContext, make_dist
        from repro.plan import exchange as pexch

        cfg = reduced(get_config("moe-gpt2"), num_layers=2, d_model=64)
        cfg = dataclasses.replace(
            cfg, compute_dtype="float32",
            moe=dataclasses.replace(cfg.moe, num_shared_experts=1))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        dist = make_dist(mesh, "decode", 8, moe_arch=True)
        B = 8
        toks = jnp.asarray(np.random.default_rng(0).integers(
            1, cfg.vocab_size, (B, 4)), jnp.int32)
    """) + textwrap.dedent(script_body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_decode_overlap_bitwise_and_plan_free_8dev():
    """Acceptance (ISSUE 8), on the 8-device golden grid: the
    decode_overlap schedule (combine psum issued concurrently with the
    shared-expert FFN through optimization_barrier) is BITWISE identical
    to sync — same value graph, same addition order — and the
    multi-device decode path performs zero build_exchange_plan calls
    (it is the plan-free all-reduce MoE)."""
    out = _run("""
        def chain(exec_mode):
            luffy = LuffyConfig(enable_condensation=False,
                                enable_migration=False,
                                exec_mode=exec_mode)
            cache = serve_lib.cache_struct(cfg, B, 8, as_struct=False)
            dec = jax.jit(lambda p, c, t: serve_lib.decode_step(
                p, cfg, luffy, dist, c, t))
            base = pexch.BUILD_CALLS
            lgs = []
            for t in range(toks.shape[1]):
                lg, cache = dec(params, cache, toks[:, t:t + 1])
                lgs.append(np.asarray(lg))
            assert pexch.BUILD_CALLS - base == 0   # decode is plan-free
            return np.asarray(lgs)

        sync = chain("sync")
        ovl = chain("decode_overlap")
        assert np.isfinite(sync).all()
        np.testing.assert_array_equal(sync, ovl)
        print("OK")
    """)
    assert "OK" in out
