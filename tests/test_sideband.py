"""_exchange_sideband round-trip property (migration sideband exchange).

The migration path silently relies on the exactly-one-writer-per-slot
invariant: ``_exchange_sideband`` scatters each sequence's side info into
a zero buffer at its destination slot and SUMS the combined buffers, so a
slot bijection must round-trip every key exactly — any double-write or
missed slot corrupts labels/seq_len/similarity history. Property-tested
single-device (pure permutation path) and checked on 8 forced host
devices through both comm modes (subprocess, like test_comm.py).
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st   # optional dep; skips when absent

from repro.core.moe_layer import _exchange_sideband

ROOT = os.path.join(os.path.dirname(__file__), "..")


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 5, 8, 16]))
def test_single_device_bijection_roundtrips_every_key(seed, n_seq):
    r = np.random.default_rng(seed)
    perm = r.permutation(n_seq).astype(np.int32)
    sb = {
        "labels": jnp.asarray(r.integers(0, 1000, (n_seq, 6)), jnp.int32),
        "seq_len": jnp.asarray(r.integers(1, 7, (n_seq,)), jnp.int32),
        "s": jnp.asarray(r.standard_normal((n_seq, 3, 3)), jnp.float32),
    }
    out = _exchange_sideband(sb, jnp.asarray(perm), n_seq, 1, None)
    assert set(out) == set(sb)
    for k, v in sb.items():
        got = np.asarray(out[k])
        # slot perm[i] now holds what slot i held before — exactly
        np.testing.assert_array_equal(got[perm], np.asarray(v))


def test_multi_device_bijection_roundtrips_every_key():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CommContext, make_mesh, shard_map
        from repro.core.moe_layer import _exchange_sideband

        n_seq, S = 4, 6
        for seed, (mode, shape, axes) in enumerate([
                ("flat", (8,), ("model",)),
                ("hier", (2, 4), ("node", "local")),
                ("flat", (8,), ("model",))]):
            M = 8
            mesh = make_mesh(shape, axes)
            ax = axes[0] if len(axes) == 1 else axes
            comm = CommContext.build(mode, ax)
            r = np.random.default_rng(seed)
            perm = r.permutation(M * n_seq).astype(np.int32)
            sb = {
                "labels": r.integers(0, 10_000, (M * n_seq, S)).astype(
                    np.int32),
                "seq_len": r.integers(1, S + 1, (M * n_seq,)).astype(
                    np.int32),
                "s": r.standard_normal((M * n_seq, 3, 3)).astype(
                    np.float32),
            }

            def inner(perm_l, lbl_l, sl_l, s_l):
                out = _exchange_sideband(
                    {"labels": lbl_l, "seq_len": sl_l, "s": s_l},
                    perm_l, n_seq, M, comm)
                return out["labels"], out["seq_len"], out["s"]

            fn = shard_map(
                inner, mesh=mesh,
                in_specs=(P(ax), P(ax, None), P(ax), P(ax, None, None)),
                out_specs=(P(ax, None), P(ax), P(ax, None, None)))
            got = fn(jnp.asarray(perm), jnp.asarray(sb["labels"]),
                     jnp.asarray(sb["seq_len"]), jnp.asarray(sb["s"]))
            for g, (k, v) in zip(got, sb.items()):
                # destination slot perm[i] holds source slot i's value
                assert np.array_equal(np.asarray(g)[perm], v), (mode, k)
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
