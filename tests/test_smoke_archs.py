"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each assigned family (<=2-ish layers, d_model<=512, <=4 experts) runs one
forward/train step and one decode step on CPU; output shapes + no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim, serve_lib, train_lib
from repro.config import LuffyConfig, OptimConfig, ShapeConfig, reduced
from repro.configs import ARCHS, get_config
from repro.core.moe_layer import capacity_for
from repro.data import SyntheticLM, make_decode_batch
from repro.dist import single_device
from repro.models.model import build_model

SHAPE = ShapeConfig("smoke", 128, 4, "train")
LUFFY = LuffyConfig(condense_group=64)
DIST = single_device()


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step(arch):
    cfg = reduced(get_config(arch))
    assert cfg.d_model <= 512 and cfg.num_layers <= 6
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, SHAPE)
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    cap = (capacity_for(cfg.moe, 4 * 128, cfg.moe.num_experts)
           if cfg.moe else 8)
    ocfg = OptimConfig(total_steps=10, warmup_steps=2)
    step = train_lib.make_train_step(cfg, LUFFY, ocfg, DIST, cap)
    ost = optim.init_opt_state(params, ocfg)
    lst = train_lib.init_luffy_state()
    p2, _, _, m = step(params, ost, lst, b)
    assert np.isfinite(float(m["loss"]))
    for leaf in jax.tree.leaves(p2):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert not bool(jnp.any(jnp.isnan(leaf))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_step(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S_max = 4, 128
    enc_len = 32 if cfg.kind == "encdec" else 0
    cache = serve_lib.cache_struct(cfg, B, S_max, enc_len=enc_len,
                                   as_struct=False)
    tok = jnp.asarray(
        make_decode_batch(cfg, ShapeConfig("d", 128, B, "decode"))["tokens"])
    logits, cache2 = serve_lib.decode_step(params, cfg, LUFFY, DIST, cache,
                                           tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    assert int(cache2["pos"]) == 1
