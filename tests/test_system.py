"""End-to-end behaviour: tiny MoE trains to falling loss with LUFFY on;
eval matches; checkpoint round-trips; serve decodes greedily."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, optim, serve_lib, train_lib
from repro.config import (LuffyConfig, OptimConfig, ShapeConfig, reduced)
from repro.configs import get_config
from repro.core.moe_layer import capacity_for
from repro.data import SyntheticLM
from repro.dist import single_device
from repro.models.model import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("moe-gpt2"), num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("t", 128, 8, "train")
    data = SyntheticLM(cfg, shape)
    return cfg, model, params, shape, data


def test_training_reduces_loss_with_luffy(setup):
    cfg, model, params, shape, data = setup
    luffy = LuffyConfig(condense_group=64)
    ocfg = OptimConfig(total_steps=30, warmup_steps=2)
    cap = capacity_for(cfg.moe, 8 * 128, cfg.moe.num_experts)
    dist = single_device()
    step = jax.jit(train_lib.make_train_step(cfg, luffy, ocfg, dist, cap))
    ost = optim.init_opt_state(params, ocfg)
    lst = train_lib.init_luffy_state()
    p = params
    losses = []
    for i in range(14):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        p, ost, lst, m = step(p, ost, lst, b)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0] - 0.3, losses
    # the adaptive threshold must have begun condensing
    assert float(m["condense_rate"]) > 0.0


def test_luffy_off_equals_eval_path(setup):
    cfg, model, params, shape, data = setup
    dist = single_device()
    cap = capacity_for(cfg.moe, 8 * 128, cfg.moe.num_experts, slack=4.0)
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    off = LuffyConfig(enable_condensation=False, enable_migration=False)
    l1, m1 = model.train_loss(params, b, jnp.float32(1.0), luffy=off,
                              dist=dist, capacity=cap)
    ev = train_lib.make_eval_step(cfg, off, dist, cap)
    m2 = ev(params, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)


def test_checkpoint_roundtrip(setup, tmp_path):
    cfg, model, params, *_ = setup
    ckpt = str(tmp_path / "ck")
    checkpoint.save(ckpt, params, step=7)
    like = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    restored, step = checkpoint.restore(ckpt, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_greedy_consistent_with_prefill(setup):
    """Prefill logits at the last position == decode-step logits after
    feeding the same tokens one by one."""
    cfg, model, params, *_ = setup
    dist = single_device()
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False)
    B, S = 2, 8
    r = np.random.default_rng(0)
    toks = jnp.asarray(r.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    lg_prefill, _ = serve_lib.prefill(params, cfg, luffy, dist, toks, S)
    cache = serve_lib.cache_struct(cfg, B, S + 4, as_struct=False)
    lg = None
    for t in range(S):
        lg, cache = serve_lib.decode_step(params, cfg, luffy, dist, cache,
                                          toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_prefill),
                               atol=2e-2, rtol=2e-2)


def test_data_pipeline_determinism(setup):
    cfg, model, params, shape, data = setup
    b1 = data.batch(3)
    b2 = SyntheticLM(cfg, shape).batch(3)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    # labels masked beyond seq_len
    lens = b1["seq_len"]
    pos = np.arange(b1["labels"].shape[1])[None]
    assert (b1["labels"][pos >= lens[:, None]] == -1).all()
