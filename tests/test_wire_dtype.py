"""Low-precision wire (``LuffyConfig.wire_dtype``, DESIGN.md §14).

Pins the ISSUE-9 contracts:

* codec round-trip properties (bf16 exact on bf16-representable rows,
  f8e4m3 bounded relative error against the block scale);
* the single pricing source — ``estimate_exchange`` scales every
  modeled byte field by exactly ``1 / wire_precision``;
* serialization v3 (wire_dtype + scale-block in the header, v2 blobs
  rejected) and cache-key membership (a dtype change is a MISS);
* the executed 8-device contracts: the bf16 wire is bit-identical to a
  reference quantize-then-exchange path, the golden grid stays within
  tolerance of the f32 wire, and the executed ``inter_bytes_shipped``
  equals ``flat / (dedup × precision)`` exactly — since ISSUE 10 in
  EVERY execution mode (vanilla, migrate, pipelined: the dedup wire is
  universal, DESIGN.md §15);
* wire error feedback (``LuffyConfig.wire_error_feedback``): residual
  shape/zero/nonzero contracts and the carried-residual step's loss
  tolerance.
"""
import os
import struct
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st   # optional dep; skips when absent

from repro.comm import dtypes as wdt
from repro.config import LuffyConfig, ModelConfig, MoEConfig

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _mk(num_experts=4, top_k=2):
    return ModelConfig(
        name="t", kind="decoder", family="moe", num_layers=2,
        d_model=32, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, d_ff=64,
                      num_shared_experts=1),
        layer_ffn_pattern=("moe",), compute_dtype="float32",
        param_dtype="float32")


# ------------------------------------------------------------ wire math

def test_wire_precision_identity_and_monotone():
    """f32 is the identity wire — row bytes reduce EXACTLY to the
    historical (d+2)·itemsize — and precision is monotone toward f8."""
    for d in (17, 32, 64, 128, 1000):
        for ce in (2, 4):
            assert wdt.wire_row_bytes(d, "f32", ce) == (d + 2) * ce
            p32 = wdt.wire_precision(d, "f32", ce)
            p16 = wdt.wire_precision(d, "bf16", ce)
            p8 = wdt.wire_precision(d, "f8e4m3", ce)
            assert p32 == 1.0
            assert 1.0 <= p16 <= p8
            # f8 sideband arithmetic: one f32 scale per 32 elements
            assert wdt.wire_row_bytes(d, "f8e4m3", ce) == \
                d + 4 * ((d + 31) // 32) + 2 * ce


def test_validate_wire_dtype():
    assert wdt.validate_wire_dtype("f32") == "f32"
    assert wdt.validate_wire_dtype("bf16") == "bf16"
    with pytest.raises(ValueError, match="wire_dtype"):
        wdt.validate_wire_dtype("fp4")
    if wdt.have_f8():
        assert wdt.validate_wire_dtype("f8e4m3") == "f8e4m3"


def test_estimate_prices_wire_exactly():
    """Single pricing source: every modeled byte field scales by exactly
    1/precision, and modeled step time is monotone non-increasing toward
    fp8 (dryrun ledger, commsim, objectives, autotune inherit free)."""
    from repro.comm.topology import Topology
    from repro.plan.estimate import estimate_exchange
    topo = Topology(2, 4)
    kw = dict(topo=topo, num_layers=2, ffn_ms=1.0)
    e32 = estimate_exchange(4096, 2, 128, **kw)
    e16 = estimate_exchange(4096, 2, 128, wire_dtype="bf16", **kw)
    prec = wdt.wire_precision(128, "bf16", 4)
    fields = ("inter_dispatch_bytes", "intra_dispatch_bytes",
              "flat_inter_dispatch_bytes", "flat_intra_dispatch_bytes")
    for f in fields:
        assert getattr(e16, f) == pytest.approx(getattr(e32, f) / prec)
    assert e16.sync_ms <= e32.sync_ms
    assert e16.dispatch_ms <= e32.dispatch_ms
    if wdt.have_f8():
        e8 = estimate_exchange(4096, 2, 128, wire_dtype="f8e4m3", **kw)
        p8 = wdt.wire_precision(128, "f8e4m3", 4)
        for f in fields:
            assert getattr(e8, f) == pytest.approx(
                getattr(e32, f) / p8)
        assert e8.sync_ms <= e16.sync_ms


# ------------------------------------------------------- codec round-trip

@settings(max_examples=40, deadline=None)
@given(st.data())
def test_quantize_roundtrip_property(data):
    """bf16 wire: exact on bf16-representable rows. f8e4m3 wire: per
    element |deq − x| ≤ blockmax/16 (half-ulp at the top of the e4m3
    range is blockmax/28), zero rows reconstruct exactly."""
    n = data.draw(st.integers(1, 8), label="rows")
    d = data.draw(st.integers(1, 70), label="d_model")
    mag = data.draw(st.sampled_from([1e-3, 1.0, 1e2]), label="magnitude")
    seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
    r = np.random.default_rng(seed)
    x = jnp.asarray((r.standard_normal((n, d)) * mag).astype(np.float32))

    xb = x.astype(jnp.bfloat16).astype(jnp.float32)   # representable
    q, sc = wdt.quantize_rows(xb, "bf16")
    assert sc is None and q.dtype == jnp.bfloat16
    back = wdt.dequantize_rows(q, sc, jnp.float32, d)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(xb))

    if not wdt.have_f8():
        return
    q, sc = wdt.quantize_rows(x, "f8e4m3")
    d_pad = wdt.pad_to_block(d)
    assert q.shape == (n, d_pad)
    assert sc.shape == (n, d_pad // wdt.SCALE_BLOCK)
    back = np.asarray(wdt.dequantize_rows(q, sc, jnp.float32, d))
    assert back.shape == (n, d)
    xp = np.zeros((n, d_pad), np.float32)
    xp[:, :d] = np.asarray(x)
    amax = np.max(np.abs(xp.reshape(n, -1, wdt.SCALE_BLOCK)), axis=-1)
    bound = np.repeat(amax / 16.0, wdt.SCALE_BLOCK, axis=-1)[:, :d]
    assert np.all(np.abs(back - np.asarray(x)) <= bound + 1e-12)
    # all-zero rows reconstruct exactly (scale pinned to 1.0)
    z = jnp.zeros((2, d), jnp.float32)
    qz, sz = wdt.quantize_rows(z, "f8e4m3")
    assert np.all(np.asarray(sz) == 1.0)
    np.testing.assert_array_equal(
        np.asarray(wdt.dequantize_rows(qz, sz, jnp.float32, d)),
        np.asarray(z))


def test_quantize_roundtrip_deterministic():
    """Non-property twin of the hypothesis test (runs when the optional
    dep is absent): same bf16-exactness and f8 error-bound contracts on
    fixed shapes."""
    r = np.random.default_rng(7)
    for n, d, mag in ((4, 33, 1.0), (2, 64, 1e-3), (8, 70, 1e2)):
        x = jnp.asarray((r.standard_normal((n, d)) * mag)
                        .astype(np.float32))
        xb = x.astype(jnp.bfloat16).astype(jnp.float32)
        q, sc = wdt.quantize_rows(xb, "bf16")
        back = wdt.dequantize_rows(q, sc, jnp.float32, d)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(xb))
        if not wdt.have_f8():
            continue
        q, sc = wdt.quantize_rows(x, "f8e4m3")
        back = np.asarray(wdt.dequantize_rows(q, sc, jnp.float32, d))
        d_pad = wdt.pad_to_block(d)
        xp = np.zeros((n, d_pad), np.float32)
        xp[:, :d] = np.asarray(x)
        amax = np.max(np.abs(xp.reshape(n, -1, wdt.SCALE_BLOCK)), -1)
        bound = np.repeat(amax / 16.0, wdt.SCALE_BLOCK, axis=-1)[:, :d]
        assert np.all(np.abs(back - np.asarray(x)) <= bound + 1e-12)


# ------------------------------------------------- serial v3 + cache key

def test_serial_v3_roundtrips_wire_dtype_and_rejects_v2():
    from repro.plan import (PlanFormatError, build_plan_template,
                            from_bytes, to_bytes)
    cfg = _mk()
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False,
                        wire_dtype="bf16")
    tmpl = build_plan_template(cfg, luffy, n_seq=2, seq_len=16,
                               capacity=64)
    assert tmpl.wire_dtype == "bf16"
    plan2 = from_bytes(to_bytes(tmpl))
    assert plan2.wire_dtype == "bf16"
    # patch the u16 format-version field to 2: rejected, never misread
    data = bytearray(to_bytes(tmpl))
    v2 = bytes(data[:4]) + struct.pack("<H", 2) + bytes(data[6:])
    with pytest.raises(PlanFormatError, match="version 2"):
        from_bytes(v2)


def test_serial_rejects_foreign_scale_block(monkeypatch):
    """A reader must never decode f8 scales computed at a different
    block size — the header pins SCALE_BLOCK."""
    from repro.plan import PlanFormatError, build_plan_template, \
        from_bytes, to_bytes
    cfg = _mk()
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False)
    data = to_bytes(build_plan_template(cfg, luffy, n_seq=2, seq_len=16,
                                        capacity=64))
    monkeypatch.setattr("repro.comm.dtypes.SCALE_BLOCK", 64)
    with pytest.raises(PlanFormatError, match="scale block"):
        from_bytes(data)


def test_plan_key_and_decode_key_miss_on_wire_dtype():
    from repro.plan import plan_key
    base = dict(n_seq=2, seq_len=16, d_model=32, capacity=64, top_k=2,
                num_experts=4, mode="vanilla", objective="traffic",
                exec_mode="sync", pipeline_chunks=1, comm_mode="local",
                topo=None, M=1)
    k32 = plan_key(**base)
    assert plan_key(**base, wire_dtype="f32") == k32   # default: no-op
    k16 = plan_key(**base, wire_dtype="bf16")
    assert k16 != k32
    assert "wdbf16" in k16
    # the serving keys thread LuffyConfig.wire_dtype through
    from repro.dist import single_device
    from repro.plan.cache import decode_plan_key, prefill_plan_key
    cfg = _mk()
    dist = single_device()
    lf = LuffyConfig(enable_condensation=False, enable_migration=False)
    lb = LuffyConfig(enable_condensation=False, enable_migration=False,
                     wire_dtype="bf16")
    assert decode_plan_key(cfg, lf, dist, 4) != \
        decode_plan_key(cfg, lb, dist, 4)
    assert prefill_plan_key(cfg, lf, dist, 2, 16) != \
        prefill_plan_key(cfg, lb, dist, 2, 16)


def test_build_plan_rejects_unknown_wire_dtype():
    from repro.plan import build_plan_template
    cfg = _mk()
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False,
                        wire_dtype="fp4")
    with pytest.raises(ValueError, match="wire_dtype"):
        build_plan_template(cfg, luffy, n_seq=2, seq_len=16, capacity=64)


# ------------------------------------------------- 8-device (subprocess)

def _run(script_body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CommContext, Topology, make_mesh, shard_map
        from repro.comm import dtypes as wdt
        from repro.configs import get_config
        from repro.config import reduced, LuffyConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.dist import DistContext, make_dist
        from repro.data import SyntheticLM
        from repro.core.moe_layer import capacity_for
    """) + textwrap.dedent(script_body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_wire_dtype_dedup_bit_identity_8dev():
    """Executed bf16 wire == reference quantize-then-exchange: the wire
    quantizes immediately before the node-crossing collective, and a
    cast/quantize commutes with row permutation — so dispatch rows must
    be BIT-identical to dequantize(quantize(dense-wire rows)). Also pins
    the fused-kernel path (use_kernel=True) bitwise against the jnp
    fallback, for every supported wire dtype."""
    out = _run("""
        from repro.condense.wire import dedup_dispatch
        from repro.core.gating import dispatch_positions

        N, L = 2, 4
        M = N * L
        mesh = make_mesh((N, L), ("node", "local"))
        topo = Topology(N, L)
        comm = CommContext.build("hier", ("node", "local"), topo)
        T, k, d, E_local, C = 48, 2, 64, 2, 24
        E = E_local * M
        r = np.random.default_rng(0)
        xf = r.standard_normal((M, T, d)).astype(np.float32)
        expert_idx = r.integers(0, E, (M, T, k)).astype(np.int32)
        gate_w = r.random((M, T, k)).astype(np.float32)
        wds = ["f32", "bf16"] + (["f8e4m3"] if wdt.have_f8() else [])

        def inner(xf_l, e_l, g_l):
            xf_l, e_l, g_l = xf_l[0], e_l[0], g_l[0]
            keep = jnp.ones((T, k), bool)
            pos = dispatch_positions(e_l, keep, E)
            valid = keep & (pos < C)
            # dense f32 reference rows through the dense wire
            pay = jnp.concatenate([
                jnp.tile(xf_l[:, None], (1, k, 1)),
                g_l[..., None]], -1).reshape(-1, d + 1)
            v_f = valid.reshape(-1)
            e_s = jnp.where(v_f, e_l.reshape(-1), 0)
            p_s = jnp.where(v_f, pos.reshape(-1), 0)
            buf = jnp.zeros((E, C, d + 1), jnp.float32).at[e_s, p_s].add(
                pay * v_f[:, None], mode="drop")
            buf = comm.all_to_all(buf)
            rows = buf.reshape(M, E_local, C, d + 1) \
                      .transpose(1, 0, 2, 3)[..., :d]
            outs = []
            for wd in wds:
                xr, gw, rv, st = dedup_dispatch(
                    xf_l, e_l, g_l, valid, pos, comm=comm,
                    e_local=E_local, capacity=C, wire_dtype=wd)
                xk, gk, _, _ = dedup_dispatch(
                    xf_l, e_l, g_l, valid, pos, comm=comm,
                    e_local=E_local, capacity=C, wire_dtype=wd,
                    use_kernel=True)
                # reference: quantize-then-exchange == exchange-then-
                # quantize for a row permutation
                q, sc = wdt.quantize_rows(rows, wd)
                want = wdt.dequantize_rows(q, sc, jnp.float32, d)
                outs += [xr, xk, want, gw, gk]
            return tuple(jnp.asarray(a)[None] for a in outs)

        fn = shard_map(inner, mesh=mesh,
                       in_specs=(P(("node", "local")),) * 3,
                       out_specs=(P(("node", "local")),) * (5 * len(wds)))
        res = fn(jnp.asarray(xf), jnp.asarray(expert_idx),
                 jnp.asarray(gate_w))
        for i, wd in enumerate(wds):
            xr, xk, want, gw, gk = res[5 * i:5 * i + 5]
            assert np.array_equal(np.asarray(xr), np.asarray(want)), (
                "wire rows not bit-identical to quantize-then-exchange "
                f"reference ({wd})")
            assert np.array_equal(np.asarray(xk), np.asarray(xr)), (
                f"fused kernel path diverges from fallback ({wd})")
            assert np.array_equal(np.asarray(gk), np.asarray(gw)), (
                f"gate rows must never quantize ({wd})")
        print("OK")
    """)
    assert "OK" in out


def test_wire_dtype_golden_grid_8dev():
    """Acceptance (ISSUE 9 + 10): on the 8-device hier mesh, the bf16
    wire trains within tolerance of f32 across {vanilla, migrate} ×
    {flat, hier} × {dedup on/off} — now ALSO the pipelined exec mode —
    gradients stay finite, and with the dedup wire on, the executed
    ``inter_bytes_shipped`` equals the modeled flat / (dedup ×
    precision) exactly IN EVERY MODE (the wire is universal, DESIGN.md
    §15: dedup never ships zero when on). fp8 (when available) is
    looser: finite loss within the documented wide tolerance."""
    out = _run("""
        cfg = reduced(get_config("moe-gpt2"), num_layers=3, d_model=128)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        shape = ShapeConfig("t", 64, 16, "train")
        data = SyntheticLM(cfg, shape)
        b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        cap = capacity_for(cfg.moe, 64, cfg.moe.num_experts, slack=8.0)
        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        dist = DistContext(mesh, batch_axes=("data", "node", "local"),
                           seq_axis=None, fsdp_axes=("data",),
                           model_axis=("node", "local"),
                           topology=Topology(2, 2))

        def loss(luffy):
            l, m = jax.jit(lambda p, bb: model.train_loss(
                p, bb, jnp.float32(0.4), luffy=luffy, dist=dist,
                capacity=cap))(params, b)
            return float(l), {k: float(v) for k, v in m.items()}

        d, ce = cfg.d_model, 4            # float32 compute
        combos = [(mig, cm, dd, "sync", 1)
                  for mig in (False, True)
                  for cm, dd in (("flat", "off"), ("hier", "off"),
                                 ("hier", "on"))]
        # ISSUE 10: the chunked dedup wire under the pipelined exchange
        combos += [(False, "hier", "on", "pipeline", 2),
                   (True, "hier", "on", "pipeline", 2)]
        for migrate, comm_mode, dedup, em, nc in combos:
            base = LuffyConfig(
                enable_condensation=True, enable_migration=migrate,
                combine_slack=4.0, condense_group=32,
                comm_mode=comm_mode, hier_dedup=dedup,
                exec_mode=em, pipeline_chunks=nc)
            l32, m32 = loss(base)
            l16, m16 = loss(dataclasses.replace(base,
                                                wire_dtype="bf16"))
            tag = (migrate, comm_mode, dedup, em)
            assert np.isfinite(l16), tag
            assert abs(l16 - l32) < 0.05, (tag, l32, l16)
            # universal-wire contract: dedup on => bytes actually ship
            # through the dedup wire, in every (mode, exec) combination
            if dedup == "on":
                assert m16["inter_bytes_shipped"] > 0, tag
            # exact executed-bytes ledger contract: shipped ==
            # dedup_bytes/precision == flat/(dedup x precision)
            if m16["inter_bytes_shipped"] > 0:
                prec = wdt.wire_precision(d, "bf16", ce)
                rows = m16["inter_bytes_dedup"] / ((d + 2) * ce)
                want = rows * wdt.wire_row_bytes(d, "bf16", ce)
                # exact up to the f32 metric accumulator: the only
                # slack is re-deriving rows from an averaged f32
                assert np.isclose(m16["inter_bytes_shipped"], want,
                                  rtol=1e-6, atol=0.0), (
                    tag, m16["inter_bytes_shipped"], want)
                assert abs(m16["inter_bytes_shipped"]
                           - m16["inter_bytes_dedup"] / prec) < 0.5
                assert m16["inter_bytes_shipped"] < \
                    m16["inter_bytes_flat"]
            else:
                assert dedup == "off", tag

        # gradients flow through the quantized wire
        ded16 = LuffyConfig(enable_condensation=True,
                            enable_migration=False, combine_slack=4.0,
                            condense_group=32, comm_mode="hier",
                            hier_dedup="on", wire_dtype="bf16")
        g = jax.jit(jax.grad(lambda p, bb: model.train_loss(
            p, bb, jnp.float32(0.4), luffy=ded16, dist=dist,
            capacity=cap)[0]))(params, b)
        gn = float(sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(g)))
        assert np.isfinite(gn) and gn > 0, gn

        # fp8: documented looser contract — finite, same ballpark
        if wdt.have_f8():
            l32, _ = loss(LuffyConfig(enable_condensation=True,
                                      enable_migration=False,
                                      combine_slack=4.0,
                                      condense_group=32,
                                      comm_mode="hier",
                                      hier_dedup="on"))
            l8, m8 = loss(LuffyConfig(enable_condensation=True,
                                      enable_migration=False,
                                      combine_slack=4.0,
                                      condense_group=32,
                                      comm_mode="hier", hier_dedup="on",
                                      wire_dtype="f8e4m3"))
            assert np.isfinite(l8), l8
            assert abs(l8 - l32) < 0.5, (l32, l8)
            rows = m8["inter_bytes_dedup"] / ((d + 2) * ce)
            want = rows * wdt.wire_row_bytes(d, "f8e4m3", ce)
            assert np.isclose(m8["inter_bytes_shipped"], want,
                              rtol=1e-6, atol=0.0), (
                m8["inter_bytes_shipped"], want)
        print("OK")
    """)
    assert "OK" in out


def test_wire_error_feedback_8dev():
    """Satellite (ISSUE 10): ``LuffyConfig.wire_error_feedback`` — the
    per-token wire quantization residual ``x − deq(quant(x))`` comes
    back per (layer, slot, position) under ``metrics["_wire_ef"]``, is
    identically zero under the exact f32 wire, nonzero under a lossy
    one, and a step fed the carried residual stays within the golden-
    grid loss tolerance of the f32 baseline (vanilla AND migrate)."""
    out = _run("""
        from repro.models import transformer as tfm
        cfg = reduced(get_config("moe-gpt2"), num_layers=3, d_model=128)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S = 16, 64
        shape = ShapeConfig("t", S, B, "train")
        data = SyntheticLM(cfg, shape)
        b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        cap = capacity_for(cfg.moe, 64, cfg.moe.num_experts, slack=8.0)
        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        dist = DistContext(mesh, batch_axes=("data", "node", "local"),
                           seq_axis=None, fsdp_axes=("data",),
                           model_axis=("node", "local"),
                           topology=Topology(2, 2))

        def loss(luffy, ef):
            l, m = jax.jit(lambda p, bb, e: model.train_loss(
                p, bb, jnp.float32(0.4), luffy=luffy, dist=dist,
                capacity=cap, wire_ef=e))(params, b, ef)
            return float(l), m

        efs = tfm.wire_ef_shape(cfg, B, S)
        ef0 = jnp.zeros(efs, jnp.float32)
        for migrate in (False, True):
            base = LuffyConfig(enable_condensation=True,
                               enable_migration=migrate,
                               combine_slack=4.0, condense_group=32,
                               comm_mode="hier", hier_dedup="on",
                               wire_error_feedback=True)
            l32, m32 = loss(base, ef0)
            # exact f32 wire: the residual is identically zero
            z = np.asarray(m32["_wire_ef"])
            assert z.shape == efs and not z.any(), (migrate, z.shape)
            lq = dataclasses.replace(base, wire_dtype="bf16")
            l1, m1 = loss(lq, ef0)
            ef1 = m1["_wire_ef"]
            e1 = np.asarray(ef1)
            assert e1.shape == efs, (migrate, e1.shape)
            assert np.isfinite(e1).all() and np.abs(e1).max() > 0, migrate
            # step 2 eats the carried residual: still within tolerance
            l2, m2 = loss(lq, ef1)
            assert np.isfinite(l2), (migrate, l2)
            assert abs(l2 - l32) < 0.05, (migrate, l32, l2)
        print("OK")
    """)
    assert "OK" in out
